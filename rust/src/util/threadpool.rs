//! Fixed-size worker pool over std::thread + channels.
//!
//! Used by the coordinator's engine (one worker per PJRT executable slot)
//! and by the parallel sweep drivers in the benches.  No async runtime is
//! available offline, and a simple pool is all the serving loop needs.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("sb-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles }
    }

    /// Submit a job; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
