//! Minimal JSON: recursive-descent parser + emitter.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), the serving
//! wire protocol, and bench result dumps.  Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (sufficient for our ASCII
//! manifests; still parses lone escapes into the replacement character).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where scanning stopped.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset into the input where the error was detected.
    pub pos: usize,
    /// Human-readable description of what went wrong.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder helpers for emitting.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.b[start..self.pos]) {
                        Ok(frag) => s.push_str(frag),
                        Err(_) => s.push('\u{fffd}'),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"moe":{"file":"m.hlo.txt","inputs":[{"dtype":"float32","shape":[512,448]}]}},"n":3,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ✓"));
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
