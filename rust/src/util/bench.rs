//! Bench harness (no criterion offline): warmup + timed iterations with
//! outlier-robust statistics and aligned table output shared by all
//! `cargo bench` targets and the CLI's bench subcommands.

use std::time::Instant;

use super::stats::Samples;

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Timing {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` with `warmup` + `iters` runs; returns robust stats.
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    let mut min = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        samples.push(ns);
        min = min.min(ns);
    }
    Timing {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        p50_ns: samples.percentile(50.0),
        p95_ns: samples.percentile(95.0),
        min_ns: min,
    }
}

/// Time a batch-style closure that reports its own work units; returns
/// (Timing, units/sec based on mean).
pub fn time_throughput<F: FnMut() -> usize>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> (Timing, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    let mut min = f64::INFINITY;
    let mut units_total = 0usize;
    for _ in 0..iters {
        let t0 = Instant::now();
        let units = f();
        let ns = t0.elapsed().as_nanos() as f64;
        units_total += units;
        samples.push(ns);
        min = min.min(ns);
    }
    let timing = Timing {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        p50_ns: samples.percentile(50.0),
        p95_ns: samples.percentile(95.0),
        min_ns: min,
    };
    let per_iter_units = units_total as f64 / iters as f64;
    let ups = per_iter_units / (timing.mean_ns / 1e9);
    (timing, ups)
}

/// Fixed-width table printer used by every bench binary so outputs diff
/// cleanly across runs (reports embed them verbatim).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_sane_numbers() {
        let t = time("noop-ish", 2, 20, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(t.iters, 20);
        assert!(t.mean_ns > 0.0);
        assert!(t.min_ns <= t.mean_ns * 1.5 + 1.0);
        assert!(t.p50_ns <= t.p95_ns);
    }

    #[test]
    fn throughput_counts_units() {
        let (_t, ups) = time_throughput("units", 1, 5, || 1000);
        assert!(ups > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["case", "tflops", "peak%"]);
        t.row(&["balanced".into(), "838.87".into(), "84.82".into()]);
        t.row(&["best".into(), "897.03".into(), "90.70".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("tflops"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
