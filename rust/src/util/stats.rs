//! Streaming statistics: Welford mean/variance, percentiles, histograms.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Reservoir of raw samples with percentile queries. For the latency volumes
/// in this crate (≤ millions) exact storage is fine and keeps p99 exact.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    v: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { v: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.v.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile by linear interpolation; `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.v.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = q / 100.0 * (self.v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.v[lo]
        } else {
            let frac = rank - lo as f64;
            self.v[lo] * (1.0 - frac) + self.v[hi] * frac
        }
    }

    pub fn mean(&self) -> f64 {
        if self.v.is_empty() {
            f64::NAN
        } else {
            self.v.iter().sum::<f64>() / self.v.len() as f64
        }
    }

    pub fn summary(&mut self) -> String {
        if self.v.is_empty() {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
            self.percentile(100.0),
        )
    }
}

/// Fixed-bucket histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Histogram { lo, hi, buckets: vec![0; n], under: 0, over: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn counts(&self) -> (&[u64], u64, u64) {
        (&self.buckets, self.under, self.over)
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.under + self.over
    }
}

/// Geometric mean of ratios — used for "ours vs baseline" aggregate speedups.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let mut w = Welford::new();
        for i in 1..=100 {
            w.push(i as f64);
        }
        assert_eq!(w.count(), 100);
        assert!((w.mean() - 50.5).abs() < 1e-9);
        // var of 1..=100 (sample) = 841.666...
        assert!((w.variance() - 841.6666666).abs() < 1e-4);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 100.0);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Samples::new();
        for i in 0..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(90.0) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Samples::new();
        s.push(0.0);
        s.push(10.0);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(99.0);
        let (b, under, over) = h.counts();
        assert!(b.iter().all(|&c| c == 1));
        assert_eq!((under, over), (1, 1));
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn geomean_of_equal_ratios() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
