//! Substrate utilities built from scratch.
//!
//! The offline vendor set ships no tokio / clap / serde / criterion /
//! proptest / rand, so this module provides the equivalents the rest of the
//! crate needs: a counter-based PRNG with the distributions the workload
//! generators use, a JSON parser/emitter for the artifact manifest, a
//! declarative CLI parser, streaming statistics, a scoped thread pool, a
//! bench harness, and a tiny property-testing driver.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod threadpool;
