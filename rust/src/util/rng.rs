//! Deterministic PRNG + the distributions used by the workload generators.
//!
//! Xoshiro256** seeded through SplitMix64 — the standard, well-tested
//! construction.  Every simulator / generator in this crate takes an explicit
//! seed so that every experiment in the DESIGN.md index is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into the xoshiro state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Lemire's unbiased bounded sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate 1.
    pub fn exponential(&mut self) -> f64 {
        -self.f64().max(1e-300).ln()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= some small value);
    /// boosted for shape < 1 with the standard u^(1/a) trick.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `n` categories.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s > 0.0 {
            for x in &mut v {
                *x /= s;
            }
        }
        v
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `alpha` (rejection-free
    /// inverse-CDF over the precomputed normalizer is overkill for our n;
    /// simple cumulative scan).
    pub fn zipf(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= *w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Zipf weights `1 / rank^alpha` for `n` items (rank 1-based).
pub fn zipf_weights(n: usize, alpha: f64) -> Vec<f64> {
    (1..=n).map(|r| 1.0 / (r as f64).powf(alpha)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for alpha in [0.1, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 16);
            assert_eq!(v.len(), 16);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn gamma_mean_is_shape() {
        let mut r = Rng::new(9);
        for shape in [0.5, 2.0, 8.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() / shape < 0.05, "shape {shape} mean {mean}");
        }
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let w = zipf_weights(100, 1.2);
        let mut r = Rng::new(21);
        let mut c0 = 0;
        let mut c99 = 0;
        for _ in 0..50_000 {
            match r.zipf(&w) {
                0 => c0 += 1,
                99 => c99 += 1,
                _ => {}
            }
        }
        assert!(c0 > 20 * (c99 + 1), "c0={c0} c99={c99}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(17);
        let s = r.sample_distinct(64, 8);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 8);
        assert!(s.iter().all(|&x| x < 64));
    }
}
