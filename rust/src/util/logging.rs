//! Minimal env-filtered logger implementing the `log` facade.
//!
//! `STATICBATCH_LOG=debug` (or `error|warn|info|debug|trace`) selects the
//! level; default is `info`.  Output goes to stderr with a monotonic
//! timestamp so serving logs interleave sanely across threads.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Level from `STATICBATCH_LOG`.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let level = match std::env::var("STATICBATCH_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger fails if already set — fine for tests calling init() twice.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
