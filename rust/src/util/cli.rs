//! Declarative CLI parsing (the vendor set has no clap).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! typed accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One registered flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_switch: bool,
}

/// A declarative command-line parser for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Command {
    name: &'static str,
    about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }

    /// Register `--name <value>` with an optional default.
    pub fn flag(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: default.map(|s| s.to_string()),
            is_switch: false,
        });
        self
    }

    /// Register a boolean `--name` switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_switch: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\nflags:");
        for f in &self.flags {
            let v = if f.is_switch {
                String::new()
            } else {
                format!(" <{}>", f.default.as_deref().unwrap_or("value"))
            };
            let _ = writeln!(s, "  --{}{:<18} {}", f.name, v, f.help);
        }
        s
    }

    /// Parse `args` (not including the subcommand itself).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut positional = Vec::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == key)
                    .ok_or_else(|| format!("unknown flag --{key}\n\n{}", self.usage()))?;
                let val = if spec.is_switch {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{key} expects a value"))?
                };
                values.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Parsed { values, positional })
    }
}

/// Parsed flag values with typed accessors.
#[derive(Clone, Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.values.get(name).cloned().unwrap_or_default()
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("bench", "run a bench")
            .flag("gpu", Some("h800"), "gpu spec")
            .flag("iters", Some("10"), "iterations")
            .switch("verbose", "extra output")
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(&args(&[])).unwrap();
        assert_eq!(p.str("gpu"), "h800");
        assert_eq!(p.usize("iters").unwrap(), 10);
        assert!(!p.bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = cmd().parse(&args(&["--gpu", "h20", "--iters=25", "--verbose"])).unwrap();
        assert_eq!(p.str("gpu"), "h20");
        assert_eq!(p.usize("iters").unwrap(), 25);
        assert!(p.bool("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cmd().parse(&args(&["--nope", "x"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&args(&["--gpu"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = cmd().parse(&args(&["file.txt", "--iters", "3"])).unwrap();
        assert_eq!(p.positional, vec!["file.txt"]);
    }

    #[test]
    fn help_returns_usage() {
        let err = cmd().parse(&args(&["--help"])).unwrap_err();
        assert!(err.contains("bench"));
        assert!(err.contains("--gpu"));
    }
}
