//! Row-major f32 tensors with just enough ops for the CPU reference
//! executor and the runtime's literal conversions.

use crate::util::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], scale: f32, rng: &mut Rng) -> Self {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * scale).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        &mut self.data[i * cols..(i + 1) * cols]
    }

    /// 3-D indexing helper: slice `[i, :, :]` of an [a, b, c] tensor as rows.
    pub fn plane(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 3);
        let sz = self.shape[1] * self.shape[2];
        &self.data[i * sz..(i + 1) * sz]
    }

    /// `C = A @ B` for 2-D tensors, fp32 accumulation.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims");
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }
}

/// Cache-blocked `C += A(mxk) @ B(kxn)`, the inner loop of the CPU executor.
/// i-k-j loop order keeps B rows hot and autovectorizes the j loop.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                c_row[j] += av * b_row[j];
            }
        }
    }
}

/// Gathered-row GEMM: `C[r, :] += tokens[idx[r], :] @ W` for each tile row r.
/// This is the token-index-array load of paper Section 4.3: no contiguous
/// copy of the gathered rows is ever materialized.
pub fn gathered_matmul_into(
    tokens: &Tensor,   // [S, K]
    idx: &[u32],       // row gather indices (len = tile rows)
    w: &[f32],         // [K, N] weight plane
    n: usize,
    c: &mut [f32],     // [tile_rows, N]
) {
    let k = tokens.shape[1];
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(c.len(), idx.len() * n);
    for (r, &src) in idx.iter().enumerate() {
        let a_row = tokens.row(src as usize);
        let c_row = &mut c[r * n..(r + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                c_row[j] += av * b_row[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let i = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0; 4]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rect() {
        let a = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![4.0, 5.0]);
    }

    #[test]
    fn gathered_matmul_matches_dense() {
        let mut rng = Rng::new(1);
        let tokens = Tensor::randn(&[8, 4], 1.0, &mut rng);
        let w = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let idx = [5u32, 0, 7, 2];
        let mut c = vec![0.0; 4 * 3];
        gathered_matmul_into(&tokens, &idx, &w.data, 3, &mut c);
        for (r, &src) in idx.iter().enumerate() {
            let row = Tensor::from_vec(&[1, 4], tokens.row(src as usize).to_vec());
            let want = row.matmul(&w);
            for j in 0..3 {
                assert!((c[r * 3 + j] - want.data[j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn plane_indexing() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.plane(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn allclose_tolerance() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0 + 1e-7, 2.0]);
        assert!(a.allclose(&b, 1e-6));
        assert!(!a.allclose(&b, 1e-9));
    }
}
